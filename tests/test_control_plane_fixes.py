"""Regression tests for control-plane edge cases (hypothesis-free, so
they run in the bare container unlike test_reward_search.py):

  * ``pad_probe_samples`` on probe windows shorter than the eval interval
    (0/1 samples, zero time span) — previously IndexError / duplicate
    time points that degenerate the reward slope fit;
  * ``log_slope_reward`` on those degenerate windows;
  * ``LegacyPolicyAdapter.fraction_for`` with a dead worker id —
    previously a bare StopIteration;
  * ``policies._speed_fraction`` with a dead worker id — same bug class;
  * ``ADSPPlus.tau_cap`` with an elastically joined worker whose stable
    id falls outside the offline grid — previously IndexError;
  * ``AdaComm`` restart — previously reused the stale loss baseline;
  * search under churn — a worker leaving/joining mid-probe-window must
    restart the SearchSession, not crash nor corrupt the SearchTrace;
  * ``ClusterEngine.evaluate``/``set_c_target`` against a policy without
    retarget support — a clear TypeError naming the policy, previously a
    silent no-op (base ClusterPolicy) or a bare AttributeError (legacy
    strategy objects).
"""

import math

import numpy as np
import pytest

from repro.control.reward import log_slope_reward
from repro.control.search import pad_probe_samples


def test_pad_probe_samples_normal_cases_unchanged():
    # ≥3 samples pass through untouched
    ts, ls = pad_probe_samples([0.0, 1.0, 2.0], [3.0, 2.0, 1.0])
    assert ts == [0.0, 1.0, 2.0] and ls == [3.0, 2.0, 1.0]
    # 2 distinct samples gain the midpoint (the original contract)
    ts, ls = pad_probe_samples([0.0, 2.0], [4.0, 2.0])
    assert ts == [0.0, 1.0, 2.0] and ls == [4.0, 3.0, 2.0]


def test_pad_probe_samples_empty_window():
    assert pad_probe_samples([], []) == ([], [])


def test_pad_probe_samples_single_sample():
    """One observation (window shorter than eval_interval): a synthetic
    flat window with distinct times — no duplicate (t, loss) points."""
    ts, ls = pad_probe_samples([7.0], [1.5])
    assert len(ts) == 3 and len(set(ts)) == 3
    assert ls == [1.5, 1.5, 1.5]
    assert ts[0] == 7.0 and ts[-1] > ts[0]


def test_pad_probe_samples_zero_time_span():
    """Two evals at the same instant (converged mid-window) must not
    produce three identical time points."""
    ts, ls = pad_probe_samples([5.0, 5.0], [1.2, 1.1])
    assert len(set(ts)) == 3
    assert all(l == 1.1 for l in ls)  # the last observation wins


def test_pad_probe_samples_does_not_mutate_inputs():
    ts_in, ls_in = [0.0, 2.0], [4.0, 2.0]
    pad_probe_samples(ts_in, ls_in)
    assert ts_in == [0.0, 2.0] and ls_in == [4.0, 2.0]


@pytest.mark.parametrize("ts,ls", [
    ([], []),
    ([7.0], [1.5]),
    ([5.0, 5.0, 5.0], [1.0, 1.0, 1.0]),
])
def test_log_slope_reward_degenerate_windows_return_zero(ts, ls):
    assert log_slope_reward(ts, ls) == 0.0


def test_log_slope_reward_padded_degenerate_pipeline():
    """End to end: degenerate window → pad → finite reward (flat ⇒ 0)."""
    for raw in ([3.0], [3.0, 3.0]):
        ts, ls = pad_probe_samples(list(np.arange(len(raw), dtype=float) * 0.0 + 2.0),
                                   list(raw))
        r = log_slope_reward(ts, ls)
        assert np.isfinite(r) and r == pytest.approx(0.0, abs=1e-12)


def test_legacy_fraction_for_dead_worker_raises_keyerror():
    from repro.cluster.engine import LegacyPolicyAdapter

    class OldStyle:
        name = "legacy"
        apply_mode = "immediate"

        def should_commit(self, view, w):
            return True

        def batch_fraction(self, view, pos):
            return 1.0

    class View:
        workers = []

    adapter = LegacyPolicyAdapter(OldStyle())
    with pytest.raises(KeyError, match="no alive worker"):
        adapter.fraction_for(View(), 42)


def test_speed_fraction_dead_worker_raises_keyerror():
    """A bare next(...) raised StopIteration, which a generator-running
    caller silently swallows as exhaustion."""
    from repro.cluster.policies import BatchTuneBSP

    class WS:
        def __init__(self, index, v):
            self.index = index
            self.profile = type("P", (), {"v": v})()

    class View:
        workers = [WS(0, 1.0), WS(2, 3.0)]  # id 1 departed

    policy = BatchTuneBSP()
    assert policy.fraction_for(View(), 2) == pytest.approx(0.75)
    with pytest.raises(KeyError, match="no alive worker"):
        policy.fraction_for(View(), 1)


def test_adsp_plus_tau_cap_survives_elastic_join():
    """tau_cap is indexed by stable worker id, dense only for the initial
    fleet: an elastic joiner (id ≥ len(tau_cap)) must run uncapped, not
    IndexError. Exercised end to end through the simulator."""
    from repro.cluster import ChurnSchedule, join, make_policy
    from repro.control.theory import WorkerProfile
    from repro.edgesim import SimConfig, Simulator
    from repro.edgesim.tasks import svm_task

    profiles = [WorkerProfile(v=1.0, o=0.2), WorkerProfile(v=2.0, o=0.2)]
    policy = make_policy("adsp_plus", gamma=20.0, tau_cap=(3, 3))
    churn = ChurnSchedule([join(15.0, WorkerProfile(v=1.0, o=0.2))])
    sim = Simulator(svm_task(2), profiles, policy,
                    SimConfig(max_seconds=80.0, base_batch=32, gamma=20.0,
                              epoch_seconds=40.0),
                    churn=churn)
    res = sim.train(80.0)
    assert len(sim.workers) == 3  # the joiner is live and training
    assert sim.workers[-1].index == 2  # id beyond the tau_cap grid
    assert res.total_commits > 0
    assert sim.workers[-1].steps > 0


def test_adacomm_restart_resets_loss_baseline():
    from repro.cluster.policies import AdaComm

    class View:
        workers = []

        @staticmethod
        def recent_global_loss():
            return 0.25

    policy = AdaComm(tau0=16)
    policy.on_started(View())
    policy.on_checkpoint(View())  # seeds the baseline
    assert policy._loss0 == 0.25 and policy._last_loss == 0.25
    policy.on_checkpoint(View())  # uses it
    # restart: both baselines must clear, not just τ
    policy.tau = 3
    policy.on_started(View())
    assert policy.tau == policy.tau0
    assert math.isnan(policy._loss0) and math.isnan(policy._last_loss)


# ---------------------------------------------------------------------------
# Search under churn (SearchSession restart semantics, end to end)
# ---------------------------------------------------------------------------


def _search_sim(churn_actions, probe_seconds=30.0, max_probes=4):
    from repro.cluster import ChurnSchedule, make_policy
    from repro.edgesim import SimConfig, Simulator
    from repro.edgesim.profiles import ratio_profiles
    from repro.edgesim.tasks import svm_task

    profiles = ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)
    policy = make_policy("adsp", gamma=20.0, search=True,
                         probe_seconds=probe_seconds, max_probes=max_probes)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    churn = ChurnSchedule(churn_actions)
    return Simulator(svm_task(len(profiles)), profiles, policy, cfg,
                     churn=churn), policy


def _assert_trace_consistent(tr):
    assert tr.chosen in tr.candidates, (tr.chosen, tr.candidates)
    # candidates climb by exactly 1 from the (re)start point
    assert tr.candidates == list(range(tr.candidates[0],
                                       tr.candidates[0] + len(tr.candidates)))
    assert len(tr.rewards) <= len(tr.candidates)
    assert all(np.isfinite(r) for r in tr.rewards)


def test_search_survives_worker_leaving_mid_probe_window():
    """A worker leaving inside a probe window must not crash the session:
    the window is discarded, the climb restarts on the surviving fleet,
    and the recorded SearchTrace stays self-consistent."""
    from repro.cluster import leave

    sim, policy = _search_sim([leave(10.0, worker=2)])
    sim.engine.epoch_end()  # churn lands inside the first probe window
    assert len(policy.traces) == 1
    tr = policy.traces[0]
    assert tr.restarts >= 1
    _assert_trace_consistent(tr)
    assert sim.num_workers == 2
    assert policy.c_target == tr.chosen
    sim.run(50.0)  # and the system keeps training normally
    assert all(w.steps > 0 for w in sim.workers)


def test_search_survives_worker_joining_mid_probe_window():
    from repro.cluster import join
    from repro.control.theory import WorkerProfile

    sim, policy = _search_sim([join(10.0, WorkerProfile(v=2.0, o=0.2))])
    sim.engine.epoch_end()
    assert len(policy.traces) == 1
    tr = policy.traces[0]
    assert tr.restarts >= 1
    _assert_trace_consistent(tr)
    assert sim.num_workers == 4
    # the joiner is folded into the restarted climb's rate rule
    assert all(w.delta_c_target >= 1 for w in sim.workers)


def test_search_aborts_cleanly_under_sustained_churn():
    """Churn in *every* probe window exhausts the restart budget: the
    session aborts (no infinite search), keeps a valid choice, and the
    trace records the abort."""
    from repro.cluster import speed

    actions = [speed(10.0 + 30.0 * k, worker=2, v=3.0 + k) for k in range(6)]
    sim, policy = _search_sim(actions)
    sim.engine.epoch_end()
    assert len(policy.traces) == 1
    tr = policy.traces[0]
    assert tr.aborted and tr.restarts >= 1
    assert tr.chosen >= 1
    assert policy.c_target == tr.chosen
    assert not sim.engine.search_active


# ---------------------------------------------------------------------------
# Retarget guard: evaluate/set_c_target against non-retargeting policies
# ---------------------------------------------------------------------------


def test_set_c_target_non_adsp_policy_raises_typeerror():
    """BSP never overrides retarget: driving Alg. 1 machinery against it
    must fail loudly (naming the policy), not silently no-op."""
    from repro.cluster import make_policy
    from repro.control.theory import WorkerProfile
    from repro.edgesim import SimConfig, Simulator
    from repro.edgesim.tasks import svm_task

    profiles = [WorkerProfile(v=1.0, o=0.2), WorkerProfile(v=2.0, o=0.2)]
    sim = Simulator(svm_task(2), profiles, make_policy("bsp"),
                    SimConfig(max_seconds=50.0, base_batch=32))
    with pytest.raises(TypeError, match="'bsp'.*does not support"):
        sim.set_c_target(3)
    with pytest.raises(TypeError, match="BSP"):
        sim.engine.evaluate(3, 5.0)


def test_legacy_policy_without_retarget_raises_typeerror():
    """A legacy strategy object (pre-engine API) without a retarget hook:
    previously AttributeError from deep inside the search."""
    from repro.cluster import SyncPolicy, coerce_policy

    class OldStyle(SyncPolicy):
        name = "third_party"

        def should_commit(self, sim, w):
            return True

    adapter = coerce_policy(OldStyle())
    assert not adapter.supports_retarget()

    from repro.cluster.engine import ClusterEngine

    eng = ClusterEngine.__new__(ClusterEngine)
    eng.policy = adapter
    with pytest.raises(TypeError, match="'third_party'"):
        eng._retarget_cmds(3)


def test_legacy_policy_with_retarget_is_delegated():
    from repro.cluster import SyncPolicy, coerce_policy

    calls = []

    class OldStyleTunable(SyncPolicy):
        name = "tunable"

        def should_commit(self, sim, w):
            return True

        def retarget(self, view, c_target):
            calls.append(c_target)

    adapter = coerce_policy(OldStyleTunable())
    assert adapter.supports_retarget()
    assert adapter.retarget(None, 7) == []
    assert calls == [7]
