"""Regression tests for control-plane edge cases (hypothesis-free, so
they run in the bare container unlike test_reward_search.py):

  * ``pad_probe_samples`` on probe windows shorter than the eval interval
    (0/1 samples, zero time span) — previously IndexError / duplicate
    time points that degenerate the reward slope fit;
  * ``log_slope_reward`` on those degenerate windows;
  * ``LegacyPolicyAdapter.fraction_for`` with a dead worker id —
    previously a bare StopIteration.
"""

import numpy as np
import pytest

from repro.core.reward import log_slope_reward
from repro.core.search import pad_probe_samples


def test_pad_probe_samples_normal_cases_unchanged():
    # ≥3 samples pass through untouched
    ts, ls = pad_probe_samples([0.0, 1.0, 2.0], [3.0, 2.0, 1.0])
    assert ts == [0.0, 1.0, 2.0] and ls == [3.0, 2.0, 1.0]
    # 2 distinct samples gain the midpoint (the original contract)
    ts, ls = pad_probe_samples([0.0, 2.0], [4.0, 2.0])
    assert ts == [0.0, 1.0, 2.0] and ls == [4.0, 3.0, 2.0]


def test_pad_probe_samples_empty_window():
    assert pad_probe_samples([], []) == ([], [])


def test_pad_probe_samples_single_sample():
    """One observation (window shorter than eval_interval): a synthetic
    flat window with distinct times — no duplicate (t, loss) points."""
    ts, ls = pad_probe_samples([7.0], [1.5])
    assert len(ts) == 3 and len(set(ts)) == 3
    assert ls == [1.5, 1.5, 1.5]
    assert ts[0] == 7.0 and ts[-1] > ts[0]


def test_pad_probe_samples_zero_time_span():
    """Two evals at the same instant (converged mid-window) must not
    produce three identical time points."""
    ts, ls = pad_probe_samples([5.0, 5.0], [1.2, 1.1])
    assert len(set(ts)) == 3
    assert all(l == 1.1 for l in ls)  # the last observation wins


def test_pad_probe_samples_does_not_mutate_inputs():
    ts_in, ls_in = [0.0, 2.0], [4.0, 2.0]
    pad_probe_samples(ts_in, ls_in)
    assert ts_in == [0.0, 2.0] and ls_in == [4.0, 2.0]


@pytest.mark.parametrize("ts,ls", [
    ([], []),
    ([7.0], [1.5]),
    ([5.0, 5.0, 5.0], [1.0, 1.0, 1.0]),
])
def test_log_slope_reward_degenerate_windows_return_zero(ts, ls):
    assert log_slope_reward(ts, ls) == 0.0


def test_log_slope_reward_padded_degenerate_pipeline():
    """End to end: degenerate window → pad → finite reward (flat ⇒ 0)."""
    for raw in ([3.0], [3.0, 3.0]):
        ts, ls = pad_probe_samples(list(np.arange(len(raw), dtype=float) * 0.0 + 2.0),
                                   list(raw))
        r = log_slope_reward(ts, ls)
        assert np.isfinite(r) and r == pytest.approx(0.0, abs=1e-12)


def test_legacy_fraction_for_dead_worker_raises_keyerror():
    from repro.cluster.engine import LegacyPolicyAdapter

    class OldStyle:
        name = "legacy"
        apply_mode = "immediate"

        def should_commit(self, view, w):
            return True

        def batch_fraction(self, view, pos):
            return 1.0

    class View:
        workers = []

    adapter = LegacyPolicyAdapter(OldStyle())
    with pytest.raises(KeyError, match="no alive worker"):
        adapter.fraction_for(View(), 42)
