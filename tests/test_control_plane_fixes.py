"""Regression tests for control-plane edge cases (hypothesis-free, so
they run in the bare container unlike test_reward_search.py):

  * ``pad_probe_samples`` on probe windows shorter than the eval interval
    (0/1 samples, zero time span) — previously IndexError / duplicate
    time points that degenerate the reward slope fit;
  * ``log_slope_reward`` on those degenerate windows;
  * ``LegacyPolicyAdapter.fraction_for`` with a dead worker id —
    previously a bare StopIteration;
  * ``policies._speed_fraction`` with a dead worker id — same bug class;
  * ``ADSPPlus.tau_cap`` with an elastically joined worker whose stable
    id falls outside the offline grid — previously IndexError;
  * ``AdaComm`` restart — previously reused the stale loss baseline.
"""

import math

import numpy as np
import pytest

from repro.core.reward import log_slope_reward
from repro.core.search import pad_probe_samples


def test_pad_probe_samples_normal_cases_unchanged():
    # ≥3 samples pass through untouched
    ts, ls = pad_probe_samples([0.0, 1.0, 2.0], [3.0, 2.0, 1.0])
    assert ts == [0.0, 1.0, 2.0] and ls == [3.0, 2.0, 1.0]
    # 2 distinct samples gain the midpoint (the original contract)
    ts, ls = pad_probe_samples([0.0, 2.0], [4.0, 2.0])
    assert ts == [0.0, 1.0, 2.0] and ls == [4.0, 3.0, 2.0]


def test_pad_probe_samples_empty_window():
    assert pad_probe_samples([], []) == ([], [])


def test_pad_probe_samples_single_sample():
    """One observation (window shorter than eval_interval): a synthetic
    flat window with distinct times — no duplicate (t, loss) points."""
    ts, ls = pad_probe_samples([7.0], [1.5])
    assert len(ts) == 3 and len(set(ts)) == 3
    assert ls == [1.5, 1.5, 1.5]
    assert ts[0] == 7.0 and ts[-1] > ts[0]


def test_pad_probe_samples_zero_time_span():
    """Two evals at the same instant (converged mid-window) must not
    produce three identical time points."""
    ts, ls = pad_probe_samples([5.0, 5.0], [1.2, 1.1])
    assert len(set(ts)) == 3
    assert all(l == 1.1 for l in ls)  # the last observation wins


def test_pad_probe_samples_does_not_mutate_inputs():
    ts_in, ls_in = [0.0, 2.0], [4.0, 2.0]
    pad_probe_samples(ts_in, ls_in)
    assert ts_in == [0.0, 2.0] and ls_in == [4.0, 2.0]


@pytest.mark.parametrize("ts,ls", [
    ([], []),
    ([7.0], [1.5]),
    ([5.0, 5.0, 5.0], [1.0, 1.0, 1.0]),
])
def test_log_slope_reward_degenerate_windows_return_zero(ts, ls):
    assert log_slope_reward(ts, ls) == 0.0


def test_log_slope_reward_padded_degenerate_pipeline():
    """End to end: degenerate window → pad → finite reward (flat ⇒ 0)."""
    for raw in ([3.0], [3.0, 3.0]):
        ts, ls = pad_probe_samples(list(np.arange(len(raw), dtype=float) * 0.0 + 2.0),
                                   list(raw))
        r = log_slope_reward(ts, ls)
        assert np.isfinite(r) and r == pytest.approx(0.0, abs=1e-12)


def test_legacy_fraction_for_dead_worker_raises_keyerror():
    from repro.cluster.engine import LegacyPolicyAdapter

    class OldStyle:
        name = "legacy"
        apply_mode = "immediate"

        def should_commit(self, view, w):
            return True

        def batch_fraction(self, view, pos):
            return 1.0

    class View:
        workers = []

    adapter = LegacyPolicyAdapter(OldStyle())
    with pytest.raises(KeyError, match="no alive worker"):
        adapter.fraction_for(View(), 42)


def test_speed_fraction_dead_worker_raises_keyerror():
    """A bare next(...) raised StopIteration, which a generator-running
    caller silently swallows as exhaustion."""
    from repro.cluster.policies import BatchTuneBSP

    class WS:
        def __init__(self, index, v):
            self.index = index
            self.profile = type("P", (), {"v": v})()

    class View:
        workers = [WS(0, 1.0), WS(2, 3.0)]  # id 1 departed

    policy = BatchTuneBSP()
    assert policy.fraction_for(View(), 2) == pytest.approx(0.75)
    with pytest.raises(KeyError, match="no alive worker"):
        policy.fraction_for(View(), 1)


def test_adsp_plus_tau_cap_survives_elastic_join():
    """tau_cap is indexed by stable worker id, dense only for the initial
    fleet: an elastic joiner (id ≥ len(tau_cap)) must run uncapped, not
    IndexError. Exercised end to end through the simulator."""
    from repro.cluster import ChurnSchedule, join, make_policy
    from repro.core.theory import WorkerProfile
    from repro.edgesim import SimConfig, Simulator
    from repro.edgesim.tasks import svm_task

    profiles = [WorkerProfile(v=1.0, o=0.2), WorkerProfile(v=2.0, o=0.2)]
    policy = make_policy("adsp_plus", gamma=20.0, tau_cap=(3, 3))
    churn = ChurnSchedule([join(15.0, WorkerProfile(v=1.0, o=0.2))])
    sim = Simulator(svm_task(2), profiles, policy,
                    SimConfig(max_seconds=80.0, base_batch=32, gamma=20.0,
                              epoch_seconds=40.0),
                    churn=churn)
    res = sim.train(80.0)
    assert len(sim.workers) == 3  # the joiner is live and training
    assert sim.workers[-1].index == 2  # id beyond the tau_cap grid
    assert res.total_commits > 0
    assert sim.workers[-1].steps > 0


def test_adacomm_restart_resets_loss_baseline():
    from repro.cluster.policies import AdaComm

    class View:
        workers = []

        @staticmethod
        def recent_global_loss():
            return 0.25

    policy = AdaComm(tau0=16)
    policy.on_started(View())
    policy.on_checkpoint(View())  # seeds the baseline
    assert policy._loss0 == 0.25 and policy._last_loss == 0.25
    policy.on_checkpoint(View())  # uses it
    # restart: both baselines must clear, not just τ
    policy.tau = 3
    policy.on_started(View())
    assert policy.tau == policy.tau0
    assert math.isnan(policy._loss0) and math.isnan(policy._last_loss)
