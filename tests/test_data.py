"""Data pipeline: determinism, shapes, worker-shard disjointness."""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import (
    WorkerShardedStream,
    chiller_like,
    cifar_like,
    fatigue_like,
    lm_tokens,
)


def test_cifar_like_shapes_and_determinism():
    x1, y1 = cifar_like(0, 100, 32)
    x2, y2 = cifar_like(0, 100, 32)
    assert x1.shape == (32, 24, 24, 3) and y1.shape == (32,)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = cifar_like(1, 100, 32)
    assert not np.allclose(x1, x3)  # different seed ⇒ different concept


def test_cifar_like_learnable_signal():
    """Class templates must be distinguishable above the noise."""
    x, y = cifar_like(0, 0, 2000, noise=0.5)
    mus = np.stack([x[y == k].mean(axis=0) for k in range(10)])
    d = np.linalg.norm(mus.reshape(10, -1)[:, None] - mus.reshape(10, -1)[None], axis=-1)
    np.fill_diagonal(d, np.inf)
    assert d.min() > 1.0  # class means well separated


def test_fatigue_like_label_correlation():
    x, cov, y = fatigue_like(0, 0, 3000)
    assert x.shape == (3000, 32) and cov.shape == (3000, 4)
    final = x[:, -1]
    assert final[y == 2].mean() > final[y == 0].mean() + 0.5


def test_chiller_like_regression_signal():
    x, cop = chiller_like(0, 0, 2000)
    assert x.shape == (2000, 6)
    # linear fit explains most of the variance
    w, *_ = np.linalg.lstsq(x, cop, rcond=None)
    resid = cop - x @ w
    assert resid.var() < 0.25 * cop.var()


@given(st.integers(0, 5), st.integers(1, 4), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_worker_shards_disjoint(seed, workers, batch):
    recorder = []

    def gen(s, start, count):
        recorder.append((start, start + count))
        return np.zeros(count)

    stream = WorkerShardedStream(gen, seed, workers)
    for w in range(workers):
        for step in range(3):
            stream(w, step, batch)
    ivals = sorted(recorder)
    for (a1, b1), (a2, b2) in zip(ivals, ivals[1:]):
        assert b1 <= a2  # non-overlapping


def test_lm_tokens_shape_and_copy_structure():
    t = lm_tokens(0, 0, 8, 64, 1000)
    assert t.shape == (8, 65) and t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 1000
    copy_rate = float((t[:, 1:] == t[:, :-1]).mean())
    assert copy_rate > 0.2  # injected Markov structure
