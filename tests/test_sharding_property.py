"""Property sweeps for the ShardPlan (mirrors the hypothesis-gated
pattern of test_rule_backends_property.py — skipped in the bare
container, exercised with the dev extras)."""

import pytest

pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st

import jax
import numpy as np

from repro.ps import ShardPlan
from repro.transport import dense_nbytes


@st.composite
def trees(draw):
    """Random nested dicts of abstract leaves with ragged shapes/dtypes."""
    n = draw(st.integers(1, 12))
    dtypes = st.sampled_from([np.float32, np.float16, np.int32])
    leaves = {}
    for i in range(n):
        rank = draw(st.integers(0, 3))
        shape = tuple(draw(st.integers(1, 64)) for _ in range(rank))
        leaf = jax.ShapeDtypeStruct(shape, draw(dtypes))
        if draw(st.booleans()):
            leaves.setdefault("nested", {})[f"leaf{i}"] = leaf
        else:
            leaves[f"leaf{i}"] = leaf
    return leaves


@given(tree=trees(), k=st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_plan_properties(tree, k):
    plan = ShardPlan.build(tree, k)
    n_leaves = len(jax.tree.leaves(tree))
    # clamped, never empty
    assert 1 <= plan.n_shards == min(k, n_leaves)
    # a partition: every leaf in exactly one shard, bytes conserved
    seen = sorted(
        i for s in range(plan.n_shards) for i in plan.shard_leaf_indices(s)
    )
    assert seen == list(range(n_leaves))
    sizes = plan.shard_nbytes()
    assert sum(sizes) == dense_nbytes(tree) == sum(plan.leaf_nbytes)
    # balance: greedy best-fit never exceeds the even split by more than
    # the largest leaf
    assert max(sizes) <= sum(sizes) / plan.n_shards + max(plan.leaf_nbytes)
    # determinism incl. abstract/concrete agreement
    assert plan == ShardPlan.build(tree, k)


@given(tree=trees(), k=st.integers(1, 8), data=st.data())
@settings(max_examples=40, deadline=None)
def test_slice_merge_roundtrip(tree, k, data):
    concrete = jax.tree.map(
        lambda a: np.zeros(a.shape, a.dtype), tree
    )
    plan = ShardPlan.build(concrete, k)
    shard = data.draw(st.integers(0, plan.n_shards - 1))
    sliced = plan.slice(concrete, shard)
    assert len(sliced) == len(plan.shard_leaf_indices(shard))
    merged = plan.merge(concrete, shard, [x + 1 for x in sliced])
    flat_in, flat_out = jax.tree.leaves(concrete), jax.tree.leaves(merged)
    idx = set(plan.shard_leaf_indices(shard))
    for i, (a, b) in enumerate(zip(flat_in, flat_out)):
        if i in idx:
            np.testing.assert_array_equal(b, a + 1)
        else:
            assert b is a
