"""Cluster ADSP commit layer: semantics on a 1-device mesh + equivalences.

(The multi-device sharding path is exercised by the dry-run and by
tests/test_dryrun_smoke.py which runs in a subprocess with fake devices.)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import use_mesh
from repro.ps import (
    AdspState,
    CommitConfig,
    UpdateRules,
    effective_momentum,
    make_train_step,
)

SEED_RULES = UpdateRules(local="sgd", commit="momentum_delta", backend="reference")


def make_adsp_step(loss_fn, cfg, mesh, batch_spec=None):
    """The seed's worker-axes ADSP step via the unified factory."""
    return make_train_step(loss_fn, cfg, SEED_RULES, mesh=mesh,
                           batch_spec=batch_spec)


def make_accum_step(loss_fn, cfg):
    """The seed's τ-step accumulation (no worker axis) via the factory."""
    return make_train_step(loss_fn, dataclasses.replace(cfg, worker_axes=()),
                           SEED_RULES)


def quad_loss(params, batch):
    x, y = batch
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2)


@pytest.fixture()
def problem():
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)
    x = rng.normal(size=(64, 4)).astype(np.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((4, 1), jnp.float32)}
    return params, (jnp.asarray(x), jnp.asarray(y))


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_adsp_step_tau1_equals_sgd(problem):
    """One worker, τ=1, no momentum ⇒ exactly W − η_g·η_l·∇ℓ."""
    params, (x, y) = problem
    cfg = CommitConfig(tau=1, local_lr=0.1, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    with use_mesh(mesh):
        step = make_adsp_step(quad_loss, cfg, mesh, batch_spec=jax.sharding.PartitionSpec(None, "data"))
        state = AdspState.create(params)
        mb = (x[None], y[None])  # tau leading dim
        tau = jnp.ones((1,), jnp.int32)
        new_state, loss = step(state, mb, tau)
    _, g = jax.value_and_grad(quad_loss)(params, (x, y))
    expect = params["w"] - 0.1 * g["w"]
    np.testing.assert_allclose(np.asarray(new_state.params["w"]), np.asarray(expect), rtol=1e-6)
    assert float(loss) == pytest.approx(float(quad_loss(params, (x, y))), rel=1e-5)


def test_adsp_step_masking(problem):
    """tau_i=1 with cfg.tau=3 must ignore microsteps 2 and 3."""
    params, (x, y) = problem
    cfg = CommitConfig(tau=3, local_lr=0.1, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    with use_mesh(mesh):
        step = make_adsp_step(quad_loss, cfg, mesh, batch_spec=jax.sharding.PartitionSpec(None, "data"))
        mb = (jnp.stack([x, x, x]), jnp.stack([y, y, y]))
        s1, _ = step(AdspState.create(params), mb, jnp.asarray([1], jnp.int32))
        s3, _ = step(AdspState.create(params), mb, jnp.asarray([3], jnp.int32))
    _, g = jax.value_and_grad(quad_loss)(params, (x, y))
    expect1 = params["w"] - 0.1 * g["w"]
    np.testing.assert_allclose(np.asarray(s1.params["w"]), np.asarray(expect1), rtol=1e-6)
    # 3 live steps move further than 1
    assert float(jnp.linalg.norm(s3.params["w"] - params["w"])) > float(
        jnp.linalg.norm(s1.params["w"] - params["w"])
    )


def test_accum_step_matches_adsp_single_worker(problem):
    params, (x, y) = problem
    cfg = CommitConfig(tau=2, local_lr=0.05, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    mb = (jnp.stack([x, x]), jnp.stack([y, y]))
    with use_mesh(mesh):
        adsp = make_adsp_step(quad_loss, cfg, mesh, batch_spec=jax.sharding.PartitionSpec(None, "data"))
        s_a, loss_a = adsp(AdspState.create(params), mb, jnp.asarray([2], jnp.int32))
    accum = make_accum_step(quad_loss, cfg)
    s_b, loss_b = accum(AdspState.create(params), mb, jnp.asarray(2, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(s_a.params["w"]), np.asarray(s_b.params["w"]), rtol=1e-6
    )
    assert float(loss_a) == pytest.approx(float(loss_b), rel=1e-6)


def test_adsp_step_converges(problem):
    params, (x, y) = problem
    cfg = CommitConfig(tau=4, local_lr=0.05, global_lr=1.0, worker_axes=("data",))
    mesh = _mesh1()
    with use_mesh(mesh):
        step = make_adsp_step(quad_loss, cfg, mesh, batch_spec=jax.sharding.PartitionSpec(None, "data"))
        state = AdspState.create(params)
        mb = (jnp.broadcast_to(x, (4, *x.shape)), jnp.broadcast_to(y, (4, *y.shape)))
        tau = jnp.asarray([4], jnp.int32)
        losses = []
        for _ in range(30):
            state, loss = step(state, mb, tau)
            losses.append(float(loss))
    assert losses[-1] < 0.01 * losses[0]


def test_effective_momentum_correction():
    cfg = CommitConfig(momentum=0.9, gamma=60.0, correct_implicit_momentum=True)
    # high commit rate ⇒ little implicit momentum ⇒ explicit ≈ target
    hi = effective_momentum(cfg, speeds=[4, 4, 4], delta_c=[30, 30, 30])
    # low rate ⇒ large implicit ⇒ explicit shrinks (floor at 0)
    lo = effective_momentum(cfg, speeds=[4, 4, 4], delta_c=[1, 1, 1])
    assert hi > lo >= 0.0
    cfg2 = CommitConfig(momentum=0.9, correct_implicit_momentum=False)
    assert effective_momentum(cfg2, [1], [1]) == 0.9
