"""Hypothesis sweeps: fused-vs-reference rule-backend parity over ragged
shapes and bfloat16/float32 params (fixed-case versions run without
hypothesis in test_update_rules.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra; pip install -e .[dev]")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.ps import CommitConfig, get_commit_rule, get_local_rule


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-6


@given(
    n=st.integers(1, 40_000),
    m=st.integers(1, 9),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    momentum=st.sampled_from([0.0, 0.5, 0.9]),
)
@settings(max_examples=20, deadline=None)
def test_ps_apply_backends_agree(n, m, dtype, momentum):
    """The fused momentum_delta commit rule matches the reference within
    dtype tolerance on ragged pytrees."""
    rng = np.random.default_rng(n * 13 + m)
    cfg = CommitConfig(tau=1, global_lr=0.3, worker_axes=())
    w = {
        "a": jnp.asarray(rng.normal(size=(n,)), dtype),
        "b": {"c": jnp.asarray(rng.normal(size=(m, 5)), dtype)},
    }
    d = jax.tree.map(lambda t: (t * 0.1).astype(t.dtype), w)
    u = jax.tree.map(lambda t: (t * 0.2 + 0.3).astype(jnp.float32), w)
    ref = get_commit_rule("momentum_delta", cfg, backend="reference")
    fus = get_commit_rule("momentum_delta", cfg, backend="fused")
    rw, rd = ref.apply(w, d, u, momentum)
    fw, fd = fus.apply(w, d, u, momentum)
    for a, b in zip(jax.tree.leaves((rw, rd)), jax.tree.leaves((fw, fd))):
        assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=_tol(dtype), rtol=_tol(dtype))


@given(n=st.integers(1, 20_000), dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=10, deadline=None)
def test_plain_average_backends_agree(n, dtype):
    rng = np.random.default_rng(n)
    cfg = CommitConfig(tau=1, global_lr=0.3, worker_axes=())
    w = {"a": jnp.asarray(rng.normal(size=(n,)), dtype)}
    u = jax.tree.map(lambda t: (t * 0.2 + 0.3).astype(jnp.float32), w)
    ref = get_commit_rule("plain_average", cfg, backend="reference")
    fus = get_commit_rule("plain_average", cfg, backend="fused")
    rw, _ = ref.apply(w, (), u, 0.0)
    fw, _ = fus.apply(w, (), u, 0.0)
    assert_allclose(np.asarray(rw["a"], np.float32), np.asarray(fw["a"], np.float32),
                    atol=_tol(dtype), rtol=_tol(dtype))


@given(
    n=st.integers(1, 30_000),
    live=st.sampled_from([0.0, 1.0]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
@settings(max_examples=12, deadline=None)
def test_sgd_local_rule_backends_agree(n, live, dtype):
    """Fused sgd microstep (param advance + U accumulation through the
    Pallas accumulate kernel) matches the reference arithmetic, including
    the τ_i mask."""
    rng = np.random.default_rng(n)
    cfg = CommitConfig(tau=1, local_lr=0.07, worker_axes=())
    p = {"w": jnp.asarray(rng.normal(size=(n,)), dtype)}
    u = jax.tree.map(jnp.zeros_like, p)
    g = jax.tree.map(lambda t: (t * 0.5 + 0.1).astype(jnp.float32), p)
    ref = get_local_rule("sgd", cfg, backend="reference")
    fus = get_local_rule("sgd", cfg, backend="fused")
    live_arr = jnp.float32(live)
    rp, ru, _ = ref.update(p, u, g, (), live_arr)
    fp, fu, _ = fus.update(p, u, g, (), live_arr)
    assert_allclose(np.asarray(rp["w"], np.float32), np.asarray(fp["w"], np.float32),
                    atol=_tol(dtype), rtol=_tol(dtype))
    assert_allclose(np.asarray(ru["w"], np.float32), np.asarray(fu["w"], np.float32),
                    atol=_tol(dtype), rtol=_tol(dtype))
