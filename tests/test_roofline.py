"""Roofline machinery: HLO collective parsing, the trip-count-aware cost
model, and the documented XLA cost_analysis loop-undercount."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import collective_bytes, model_flops, roofline_terms, xla_cost_dict
from repro.roofline.hlo_cost import module_cost


def test_collective_parser_on_synthetic_hlo():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[128,4]{1,0} all-reduce(%p), to_apply=%sum
  %ag = bf16[256]{0} all-gather(%p), dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%p), dimensions={0}
  %a2a = f32[32]{0} all-to-all(%p), dimensions={0}
  %cp = f32[16]{0} collective-permute(%p)
}
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 4 * 4 * 2.0  # ring factor 2
    assert got["all-gather"] == 256 * 2
    assert got["reduce-scatter"] == 64 * 4
    assert got["all-to-all"] == 32 * 4
    assert got["collective-permute"] == 16 * 4
    assert got["total"] == sum(
        got[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_xla_cost_analysis_undercounts_loops_and_we_correct_it():
    def body(c, _):
        return c @ c, None

    def f_scan(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_unroll(x):
        for _ in range(10):
            x = x @ x
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cs = jax.jit(f_scan).lower(x).compile()
    cu = jax.jit(f_unroll).lower(x).compile()
    xla_scan = xla_cost_dict(cs)["flops"]
    xla_unroll = xla_cost_dict(cu)["flops"]
    assert xla_unroll == pytest.approx(10 * xla_scan, rel=0.01)  # the bug
    ours_scan = module_cost(cs.as_text()).flops
    ours_unroll = module_cost(cu.as_text()).flops
    assert ours_scan == pytest.approx(xla_unroll, rel=0.05)  # the fix
    assert ours_unroll == pytest.approx(xla_unroll, rel=0.05)


def test_module_cost_loop_free_matches_xla():
    def f(a, b):
        return jax.nn.relu(a @ b).sum()

    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    comp = jax.jit(f).lower(a, b).compile()
    ours = module_cost(comp.as_text())
    theirs = xla_cost_dict(comp)
    assert ours.flops == pytest.approx(theirs["flops"], rel=0.2)


def test_nested_scan_trip_counts():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(x).compile()
    got = module_cost(comp.as_text()).flops
    assert got == pytest.approx(20 * 2 * 64**3, rel=0.05)


def test_roofline_terms_and_bottleneck():
    from repro.launch.specs import SHAPES
    from repro.configs import get_config

    cfg = get_config("granite_3_8b")
    mf = model_flops(cfg, SHAPES["train_4k"], tau=4)
    assert mf == pytest.approx(6 * cfg.active_params() * 4 * 256 * 4096, rel=1e-6)
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %d = f32[1024,1024]{1,0} dot(%p, %p), lhs_contracting_dims={0}, rhs_contracting_dims={0}
}
"""
    rep = roofline_terms(
        arch="a", shape="train_4k", mesh_name="single", n_chips=256,
        cost={}, hlo_text=hlo, model_flops_total=mf,
    )
    assert rep.bottleneck in ("compute", "memory", "collective")
    assert rep.compute_s >= 0 and rep.memory_s >= 0


def test_decode_model_flops_counts_one_token():
    from repro.launch.specs import SHAPES
    from repro.configs import get_config

    cfg = get_config("rwkv6_3b")
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    f_pre = model_flops(cfg, SHAPES["prefill_32k"])
    assert f_pre / f_dec == pytest.approx(32 * 32768 / 128, rel=1e-6)
