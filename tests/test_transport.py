"""The commit-transport layer (repro.transport): codec contracts, the
link model, and the wiring through both the simulator and the real train
step.

Key invariants:
  * error feedback: decode(enc) + new_residual == update + residual;
  * identity codec + infinite bandwidth == the pre-transport stack,
    bit for bit (timing, losses, and the old bytes proxy);
  * fused (Pallas) and reference backends agree from a real train step;
  * on a bandwidth-constrained link, int8 cuts measured bytes_to_ps ~4×
    with no worse convergence time.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.compat import use_mesh
from repro.control.theory import WorkerProfile
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import ratio_profiles, with_links
from repro.edgesim.tasks import svm_task
from repro.cluster import make_policy
from repro.ps import AdspState, CommitConfig, UpdateRules, make_train_step
from repro.transport import (
    Codec,
    codec_backends,
    codec_names,
    dense_nbytes,
    get_codec,
)


@pytest.fixture()
def update_tree():
    rng = np.random.default_rng(3)
    return {
        "a": jnp.asarray(rng.normal(size=(1001,)), jnp.float32),
        "b": {"c": jnp.asarray(rng.normal(size=(17, 5)), jnp.float32)},
    }


def _all_codecs():
    out = []
    for name in codec_names():
        for backend in codec_backends(name):
            out.append((name, backend))
    return out


# ---------------------------------------------------------------------------
# codec contracts
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(codec_names()) >= {"identity", "int8", "bf16", "top_k"}
    assert codec_backends("int8") == ("fused", "reference")
    assert codec_backends("top_k") == ("reference",)
    # a fused request for a codec with no fused impl falls back
    assert get_codec("top_k", backend="fused").backend == "reference"
    # Codec instances pass through; unknown names raise
    c = get_codec("int8", backend="reference")
    assert get_codec(c) is c
    with pytest.raises(KeyError):
        get_codec("gzip")


@pytest.mark.parametrize("name,backend", _all_codecs())
def test_error_feedback_identity(update_tree, name, backend):
    """decode(encode(e)) + residual' == e, the invariant that keeps lossy
    codecs unbiased across commits."""
    codec = get_codec(name, backend=backend)
    state = codec.init(update_tree)
    enc, state1 = codec.encode(update_tree, state)
    dec = codec.decode(enc, update_tree)
    res = state1 if jax.tree.leaves(state1) else jax.tree.map(
        jnp.zeros_like, update_tree
    )
    for d, r, u in zip(jax.tree.leaves(dec), jax.tree.leaves(res),
                       jax.tree.leaves(update_tree)):
        assert_allclose(np.asarray(d) + np.asarray(r), np.asarray(u),
                        atol=1e-6, rtol=1e-6)


def test_identity_is_exact_passthrough(update_tree):
    codec = get_codec("identity")
    enc, state = codec.encode(update_tree, codec.init(update_tree))
    assert enc is update_tree  # not a copy: bit-parity by construction
    assert codec.decode(enc, update_tree) is update_tree


def test_encoded_nbytes_static(update_tree):
    n = 1001 + 17 * 5
    dense = dense_nbytes(update_tree)
    assert dense == 4 * n
    assert get_codec("identity").encoded_nbytes(update_tree) == dense
    assert get_codec("int8").encoded_nbytes(update_tree) == n + 2 * 4
    assert get_codec("bf16").encoded_nbytes(update_tree) == 2 * n
    k = max(1, round(0.05 * 1001)) + max(1, round(0.05 * 85))
    assert get_codec("top_k", frac=0.05).encoded_nbytes(update_tree) == 8 * k


def test_error_feedback_recovers_lost_mass(update_tree):
    """A constant update stream through int8 must not drift: the running
    sum of decoded commits tracks the running sum of true updates."""
    codec = get_codec("int8")
    state = codec.init(update_tree)
    acc = jax.tree.map(jnp.zeros_like, update_tree)
    for _ in range(8):
        enc, state = codec.encode(update_tree, state)
        acc = jax.tree.map(jnp.add, acc, codec.decode(enc, update_tree))
    for a, u in zip(jax.tree.leaves(acc), jax.tree.leaves(update_tree)):
        # without error feedback the quantization error would be ~8× larger
        assert_allclose(np.asarray(a), 8 * np.asarray(u), atol=0.02, rtol=0.01)


def test_codec_kernel_ops_match_plain_math(update_tree):
    """The per-array kernel ops behind the fused codecs — quantize_int8,
    dequantize_int8, encode_bf16 — against their plain-jnp definitions."""
    from repro.kernels import ops

    x = jax.tree.leaves(update_tree)[0]
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    q, res = ops.quantize_int8(x, scale)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    expect_q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    assert_array_equal(np.asarray(q), np.asarray(expect_q))
    dec = ops.dequantize_int8(q, scale)
    assert_allclose(np.asarray(dec), np.asarray(q, np.float32) * scale,
                    atol=1e-6, rtol=1e-6)
    # residual carries exactly what the round trip lost
    assert_allclose(np.asarray(dec) + np.asarray(res), np.asarray(x),
                    atol=1e-6, rtol=1e-6)

    qb, rb = ops.encode_bf16(x)
    assert qb.dtype == jnp.bfloat16 and qb.shape == x.shape
    assert_array_equal(np.asarray(qb), np.asarray(x.astype(jnp.bfloat16)))
    assert_allclose(np.asarray(qb, np.float32) + np.asarray(rb),
                    np.asarray(x), atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("shape", [(32, 1024), (257,), (3, 5), (40_000,)])
def test_fused_codec_commit_kernel_ops_match_ref(shape):
    """The single-pass commit-path kernels (§16) — "quantize_int8_ef",
    "encode_bf16_ef", "int8_decode_apply", "bf16_decode_apply",
    "int8_decode_accum", "bf16_decode_accum" — bit-for-bit against their
    ref.py twins, which spell out the exact unfused chain. The twins run
    under jit like every real call site (eager mode skips XLA's FMA
    contraction of e − q·s and differs below one ulp of e)."""
    from repro.kernels import ops
    from repro.kernels import ref as _ref

    class ref:  # jit each twin: compare the compiled forms, as deployed
        pass
    for _n in ("quantize_int8_ef", "encode_bf16_ef", "int8_decode_apply",
               "bf16_decode_apply", "int8_decode_accum", "bf16_decode_accum"):
        setattr(ref, _n, staticmethod(jax.jit(getattr(_ref, _n))))

    rng = np.random.default_rng(int(np.prod(shape)))
    u = jnp.asarray(rng.normal(size=shape), jnp.float32)
    r = jnp.asarray(rng.normal(size=shape) * 0.01, jnp.float32)
    w = jnp.asarray(rng.normal(size=shape), jnp.float32)
    d = jnp.asarray(rng.normal(size=shape) * 0.1, jnp.float32)
    lr, mu = 0.7, 0.9

    scale = float(jnp.max(jnp.abs(u + r))) / 127.0
    q, res = ops.quantize_int8_ef(u, r, scale, interpret=True)
    q_e, res_e = ref.quantize_int8_ef(u, r, scale)
    assert q.dtype == jnp.int8
    assert_array_equal(np.asarray(q), np.asarray(q_e))
    assert_array_equal(np.asarray(res), np.asarray(res_e))

    qb, rb = ops.encode_bf16_ef(u, r, interpret=True)
    qb_e, rb_e = ref.encode_bf16_ef(u, r)
    assert qb.dtype == jnp.bfloat16
    assert_array_equal(np.asarray(qb, np.float32), np.asarray(qb_e, np.float32))
    assert_array_equal(np.asarray(rb), np.asarray(rb_e))

    nw, nd = ops.int8_decode_apply(w, d, q, scale, lr, mu, interpret=True)
    ew, ed = ref.int8_decode_apply(w, d, q, scale, lr, mu)
    assert_array_equal(np.asarray(nw), np.asarray(ew))
    assert_array_equal(np.asarray(nd), np.asarray(ed))

    nw, nd = ops.bf16_decode_apply(w, d, qb, lr, mu, interpret=True)
    ew, ed = ref.bf16_decode_apply(w, d, qb, lr, mu)
    assert_array_equal(np.asarray(nw), np.asarray(ew))
    assert_array_equal(np.asarray(nd), np.asarray(ed))

    aw = ops.int8_decode_accum(w, q, scale, lr, interpret=True)
    assert_array_equal(np.asarray(aw),
                       np.asarray(ref.int8_decode_accum(w, q, scale, lr)))
    aw = ops.bf16_decode_accum(w, qb, lr, interpret=True)
    assert_array_equal(np.asarray(aw),
                       np.asarray(ref.bf16_decode_accum(w, qb, lr)))


def test_as_tiles_skips_copy_for_aligned_leaves():
    """A leaf whose size is already a tile multiple passes through
    _as_tiles/_from_tiles untouched — the same buffer, no pad/reshape
    copy — while ragged leaves still take the padded path."""
    from repro.kernels import ops
    from repro.kernels.codec import QBLOCK

    x = jnp.ones(QBLOCK, jnp.float32)  # exactly one tile
    t, n = ops._as_tiles(x, QBLOCK)
    assert t is x and n == x.size
    assert ops._from_tiles(t, n, x.shape, x.dtype) is t

    big = jnp.ones((4 * QBLOCK[0], QBLOCK[1]), jnp.float32)
    t, _ = ops._as_tiles(big, QBLOCK)
    assert t is big

    ragged = jnp.ones((257,), jnp.float32)
    t, n = ops._as_tiles(ragged, QBLOCK)
    assert t is not ragged and t.shape == QBLOCK and n == 257
    back = ops._from_tiles(t, n, ragged.shape, ragged.dtype)
    assert_array_equal(np.asarray(back), np.asarray(ragged))


def test_overlapped_shard_pulls_donate_param_buffers():
    """The overlapped commit's per-shard pull jits carry
    donate_argnums=(0, 1): each shard's params and commit state are dead
    the moment the fused apply produces their successors, so the round
    updates in place. Verified by buffer identity — after a (warm)
    round, every new param leaf occupies one of the previous round's
    buffers, i.e. donation actually took effect rather than being
    silently dropped."""
    from repro.cluster import ADSP, ClusterEngine
    from repro.cluster.mesh_backend import MeshBackend, MeshTask

    def quad_loss(params, batch):
        x, y = batch
        return jnp.mean((x @ params["w"] + params["b"] - y) ** 2)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 1)), jnp.float32)
    task = MeshTask(
        init_params={"w": jnp.zeros((4, 1), jnp.float32),
                     "b": jnp.zeros((1,), jnp.float32)},
        loss_fn=quad_loss,
        make_microbatches=lambda r, tau, n: (jnp.stack([x] * tau),
                                             jnp.stack([y] * tau)),
    )
    mesh = jax.make_mesh((1,), ("data",))
    backend = MeshBackend(task, mesh, tau=2, codec="bf16", n_shards=2,
                          fused_commit=True, overlap_shards=True)
    ClusterEngine(ADSP(search=False, gamma=4.0), backend)
    with use_mesh(mesh):
        backend.run_round()  # warm the push/pull jits (first call compiles)
        before = {leaf.unsafe_buffer_pointer()
                  for leaf in jax.tree.leaves(backend.state.params)}
        backend.run_round()
        after = [leaf.unsafe_buffer_pointer()
                 for leaf in jax.tree.leaves(backend.state.params)]
    assert all(p in before for p in after), (
        "per-shard pull did not reuse donated param buffers")


@pytest.mark.parametrize("name", ["int8", "bf16"])
def test_fused_matches_reference_encode_decode(update_tree, name):
    ref = get_codec(name, backend="reference")
    fus = get_codec(name, backend="fused")
    assert fus.backend == "fused"
    s0 = ref.init(update_tree)
    enc_r, st_r = ref.encode(update_tree, s0)
    enc_f, st_f = fus.encode(update_tree, s0)
    for a, b in zip(jax.tree.leaves((enc_r, st_r)), jax.tree.leaves((enc_f, st_f))):
        assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        atol=1e-6, rtol=1e-6)
    dec_r = ref.decode(enc_r, update_tree)
    dec_f = fus.decode(enc_f, update_tree)
    for a, b in zip(jax.tree.leaves(dec_r), jax.tree.leaves(dec_f)):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# the real train step
# ---------------------------------------------------------------------------

def quad_loss(params, batch):
    x, y = batch
    return jnp.mean((x @ params["w"] - y) ** 2)


@pytest.fixture()
def problem():
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(4, 1)).astype(np.float32)
    x = rng.normal(size=(64, 4)).astype(np.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((4, 1), jnp.float32)}
    return params, (jnp.asarray(x), jnp.asarray(y))


def _run_steps(problem, codec, rounds=4, backend="reference"):
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.1, global_lr=1.0, worker_axes=("data",))
    mesh = jax.make_mesh((1,), ("data",))
    mbs = (jnp.stack([batch[0]] * 2), jnp.stack([batch[1]] * 2))
    step = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                           mesh=mesh, codec=codec)
    with use_mesh(mesh):
        state = step.init(params)
        for _ in range(rounds):
            state, loss = jax.jit(step)(state, mbs, jnp.asarray([2], jnp.int32))
    return np.asarray(state.params["w"]), float(loss)


def test_train_step_identity_codec_bit_identical(problem):
    w_none, l_none = _run_steps(problem, codec=None)
    w_id, l_id = _run_steps(problem, codec="identity")
    assert_array_equal(w_none, w_id)
    assert l_none == l_id


@pytest.mark.parametrize("codec", ["int8", "bf16", "top_k"])
def test_train_step_lossy_codec_still_converges(problem, codec):
    w, loss = _run_steps(problem, codec=codec, rounds=30)
    assert loss < 0.05  # quad problem: near-exact recovery despite compression


def test_train_step_fused_codec_matches_reference(problem):
    params, batch = problem
    cfg = CommitConfig(tau=2, local_lr=0.1, global_lr=1.0, worker_axes=("data",))
    mesh = jax.make_mesh((1,), ("data",))
    mbs = (jnp.stack([batch[0]] * 2), jnp.stack([batch[1]] * 2))
    outs = {}
    for backend in ("reference", "fused"):
        step = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                               mesh=mesh, codec=get_codec("int8", backend=backend))
        with use_mesh(mesh):
            state = step.init(params)
            for _ in range(3):
                state, loss = jax.jit(step)(state, mbs, jnp.asarray([2], jnp.int32))
        outs[backend] = (np.asarray(state.params["w"]), float(loss))
    assert_allclose(outs["fused"][0], outs["reference"][0], atol=1e-6, rtol=1e-6)
    assert outs["fused"][1] == pytest.approx(outs["reference"][1], rel=1e-6)


def test_transport_state_mismatch_raises(problem):
    params, batch = problem
    cfg = CommitConfig(tau=1, local_lr=0.1, worker_axes=("data",))
    mesh = jax.make_mesh((1,), ("data",))
    mbs = (jnp.stack([batch[0]]), jnp.stack([batch[1]]))
    step = make_train_step(quad_loss, cfg, UpdateRules(backend="reference"),
                           mesh=mesh, codec="int8")
    with use_mesh(mesh):
        with pytest.raises(ValueError, match="transport_state does not match"):
            step(AdspState.create(params), mbs, jnp.ones((1,), jnp.int32))


def test_cli_codec_args():
    import argparse

    from repro.transport import add_codec_args, codec_from_args

    p = argparse.ArgumentParser()
    add_codec_args(p)
    c = codec_from_args(p.parse_args([]))
    assert isinstance(c, Codec) and c.name == "identity"
    c = codec_from_args(p.parse_args(
        ["--codec", "top_k", "--topk-frac", "0.25", "--codec-backend", "reference"]))
    assert c.name == "top_k"


# ---------------------------------------------------------------------------
# the simulator link model
# ---------------------------------------------------------------------------

def _sim(codec="identity", profiles=None, seconds=240.0, policy=None, **cfg_kw):
    profiles = profiles or ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)
    cfg = SimConfig(max_seconds=seconds, base_batch=32, gamma=20.0,
                    epoch_seconds=80.0, **cfg_kw)
    policy = policy or make_policy("adsp", search=False, gamma=20.0)
    sim = Simulator(svm_task(len(profiles)), profiles, policy, cfg, codec=codec)
    return sim, sim.train(seconds)


def test_identity_infinite_bandwidth_matches_fixed_o():
    """The old fixed-O_i commit cost and bytes proxy, reproduced exactly:
    comm_time is commits·O_i per worker and bytes_to_ps is 4·|params|·C."""
    sim, res = _sim("identity")
    for w in sim.workers:
        # every charged commit round trip cost exactly o (o/2 + o/2)
        charged = w.comm_time / w.profile.o
        assert charged == pytest.approx(round(charged))
    assert res.bytes_to_ps == 4.0 * sim._param_sizes * sim.total_commits


def test_worker_profile_link_validation():
    with pytest.raises(ValueError):
        WorkerProfile(v=1.0, bandwidth=0.0)
    with pytest.raises(ValueError):
        WorkerProfile(v=1.0, latency=-1.0)
    p = WorkerProfile(v=1.0, o=0.2, bandwidth=100.0, latency=0.05)
    assert p.transfer_seconds(50) == pytest.approx(0.55)
    assert WorkerProfile(v=1.0).transfer_seconds(1e12) == 0.0  # inf link


def test_constrained_link_charges_payload_time():
    """With bandwidth B and latency L, each commit costs
    o + 2L + (enc + dense)/B of comm time."""
    profiles = with_links(ratio_profiles((1.0,), base_v=1.0, o=0.2),
                          bandwidth=1000.0, latency=0.05)
    sim, res = _sim("identity", profiles=profiles, seconds=60.0)
    w = sim.workers[0]
    per_commit = (w.profile.o + 2 * 0.05
                  + (sim._enc_nbytes + sim._pull_nbytes) / 1000.0)
    assert w.commits > 0
    # comm_time counts in-flight commits too; allow one round trip slack
    charged = w.comm_time / per_commit
    assert charged == pytest.approx(round(charged))
    assert round(charged) >= w.commits


def test_int8_reduces_bytes_no_worse_convergence():
    """The acceptance tradeoff on a link-bound fleet: int8 cuts wire bytes
    ~4× and converges no later than the dense identity run."""
    task_params_bytes = dense_nbytes(svm_task(3).init_params)
    profiles = with_links(ratio_profiles((1, 1, 3), base_v=1.0, o=0.2),
                          bandwidth=task_params_bytes / 1.0, latency=0.02)
    _, res_id = _sim("identity", profiles=profiles, target_loss=0.55)
    _, res_q = _sim("int8", profiles=profiles, target_loss=0.55)
    assert res_q.converged and res_id.converged
    # the tiny SVM (7 params, 2 leaves) pays 4 B of scale per leaf, so the
    # ratio is ~1.9× here rather than the asymptotic 4× (bench_transport
    # shows 4× on the CNN)
    assert res_q.bytes_to_ps < 0.6 * res_id.bytes_to_ps
    assert res_q.convergence_time <= res_id.convergence_time * 1.05


def test_simulator_rejects_unknown_codec():
    with pytest.raises(KeyError):
        _sim("gzip", seconds=1.0)


# ---------------------------------------------------------------------------
# the mesh backend
# ---------------------------------------------------------------------------

def test_mesh_backend_codec_bytes_accounting():
    from repro.cluster import ADSP, ClusterEngine
    from repro.cluster.mesh_backend import MeshBackend, MeshTask

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 1)), jnp.float32)

    task = MeshTask(
        init_params={"w": jnp.zeros((4, 1), jnp.float32)},
        loss_fn=quad_loss,
        make_microbatches=lambda r, tau, n: (jnp.stack([x] * tau), jnp.stack([y] * tau)),
    )
    mesh = jax.make_mesh((1,), ("data",))
    backend = MeshBackend(task, mesh, tau=2, codec="int8")
    ClusterEngine(ADSP(search=False, gamma=4.0), backend)
    with use_mesh(mesh):
        backend.train(rounds=3)
    assert backend.codec.name == "int8"
    assert backend.bytes_per_round == backend.codec.encoded_nbytes(task.init_params)
    assert backend.bytes_to_ps == 3 * backend.bytes_per_round
    assert backend.bytes_per_round < dense_nbytes(task.init_params)
