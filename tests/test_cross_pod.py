"""Cross-pod collective attribution (roofline.hlo_cost replica-group
parsing) — the machinery behind the §Perf multi-pod finding."""

from repro.roofline.hlo_cost import _group_crosses_boundary, module_cost


def test_iota_groups_within_pod():
    # [32,16]<=[512]: contiguous groups of 16 — never cross the 256 edge
    attrs = ", replica_groups=[32,16]<=[512], channel_id=1"
    assert not _group_crosses_boundary(attrs, 256)


def test_iota_groups_crossing_pod():
    # [256,2]<=[2,256]T(1,0): pairs (i, i+256) — every group crosses
    attrs = ", replica_groups=[256,2]<=[2,256]T(1,0), channel_id=1"
    assert _group_crosses_boundary(attrs, 256)


def test_explicit_groups():
    within = ", replica_groups={{0,1,2,3},{4,5,6,7}}, channel_id=2"
    across = ", replica_groups={{0,256},{1,257}}, channel_id=2"
    assert not _group_crosses_boundary(within, 256)
    assert _group_crosses_boundary(across, 256)


def test_module_cost_cross_pod_accounting():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar1 = f32[1024]{0} all-reduce(%p), replica_groups=[32,16]<=[512], to_apply=%s
  %ar2 = f32[1024]{0} all-reduce(%p), replica_groups=[256,2]<=[2,256]T(1,0), to_apply=%s
}
"""
    c = module_cost(hlo, pod_boundary=256)
    # both ARs weighted 2×4096 bytes; only ar2 is cross-pod
    assert c.coll["all-reduce"] == 2 * 2 * 4096
    assert c.coll_cross == 2 * 4096
    c0 = module_cost(hlo, pod_boundary=0)  # single-pod: no attribution
    assert c0.coll_cross == 0
