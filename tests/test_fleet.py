"""Fleet orchestration (repro.fleet, DESIGN.md §13): heartbeat/lease
failure discovery, capability-aware scheduling, and the structured
metrics stream — unit level plus end to end through the edge simulator.

The lease edge cases pinned here:

  * silent stall → exactly one ``WorkerLeft(discovered=True)`` at the
    last heartbeat arrival + TTL, and a barrier fleet *unblocks*;
  * a healthy worker on a congested link whose heartbeat delivery
    overshoots the TTL flaps — the tracker models the false positive
    faithfully instead of forbidding it;
  * recover *before* expiry is invisible (no discovered events at all);
  * recover *after* expiry is a discovered rejoin with a state catch-up
    and the offline span excluded from the active-time accounting;
  * lease expiry inside an Alg. 1 probe window restarts the
    SearchSession (the silent stall alone, with no lease layer, does
    not — that contrast is the regression);
  * a scripted leave racing a missed lease dedupes to ONE WorkerLeft in
    either order (discovery first, or administrative notice first).
"""

import importlib.util
import json
import math
import pathlib

import numpy as np
import pytest

from repro.cluster import ChurnSchedule, churn, make_policy
from repro.control.theory import WorkerProfile
from repro.edgesim import SimConfig, Simulator
from repro.edgesim.profiles import fleet_profiles, ratio_profiles
from repro.edgesim.tasks import svm_task
from repro.fleet import (
    AssignRecord,
    CapabilityRecord,
    ChurnRecord,
    CommitRecord,
    DriftRecord,
    EvalRecord,
    FleetConfig,
    JsonlSink,
    LeaseConfig,
    LeaseRecord,
    LeaseTracker,
    MetricsLog,
    PullRecord,
    SearchRecord,
    ServeRecord,
    from_dict,
    get_scheduler,
    load_jsonl,
    record_kinds,
    scheduler_names,
    to_dict,
)

# ttl=6, period=2 with a zero-delay link: a worker stalling at t has its
# last heartbeat arrive at floor(t/2)*2 and its lease expire ttl later.
LEASE = LeaseConfig(ttl=6.0, heartbeat_period=2.0)


def _fleet_sim(actions, *, policy=None, fleet=None, metrics=None,
               n_shards=1, profiles=None):
    profiles = profiles or ratio_profiles((1.0, 1.0, 1.0), base_v=1.0, o=0.2)
    cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    return Simulator(svm_task(len(profiles)), profiles,
                     policy or make_policy("bsp"), cfg,
                     churn=ChurnSchedule(actions) if actions else None,
                     n_shards=n_shards, fleet=fleet, metrics=metrics)


# ---------------------------------------------------------------------------
# Lease life cycle through the simulator
# ---------------------------------------------------------------------------


def test_lease_expiry_discovers_silent_stall():
    """A silent stall produces no WorkerLeft by itself; the lease layer
    synthesizes exactly one discovered departure at last-heartbeat + TTL,
    and the BSP barrier (blocked on the dead worker) releases."""
    log = MetricsLog()
    sim = _fleet_sim([churn.stall(10.0, worker=1)],
                     fleet=FleetConfig(lease=LEASE), metrics=log)
    sim.run(40.0)
    granted = [r for r in log.of("lease") if r.event == "granted"]
    assert sorted(r.worker for r in granted) == [0, 1, 2]
    stalled = [r for r in log.of("lease") if r.event == "stalled"]
    assert [(r.worker, r.t) for r in stalled] == [(1, 10.0)]
    expired = [r for r in log.of("lease") if r.event == "expired"]
    # last heartbeat sent at the stall instant t=10 still delivers
    assert [(r.worker, r.t) for r in expired] == [(1, 16.0)]
    disc = [r for r in log.of("churn") if r.discovered]
    assert [(r.event, r.worker, r.t) for r in disc] == [("leave", 1, 16.0)]
    assert sim.num_workers == 2
    # the survivors kept training past the barrier the dead worker held
    assert all(w.steps > 0 for w in sim.workers)


def test_congested_link_flaps_like_a_death():
    """False positive: a perfectly healthy worker whose link delay pushes
    every heartbeat past the TTL is indistinguishable from a death — the
    lease layer evicts it (the documented TTL-misconfiguration mode)."""
    profiles = [WorkerProfile(v=1.0, o=0.2), WorkerProfile(v=1.0, o=0.2),
                WorkerProfile(v=1.0, o=0.2, latency=7.0)]  # delay 7 > ttl 6
    log = MetricsLog()
    sim = _fleet_sim([], profiles=profiles,
                     fleet=FleetConfig(lease=LEASE), metrics=log)
    sim.run(20.0)
    disc = [r for r in log.of("churn") if r.discovered and r.event == "leave"]
    assert [r.worker for r in disc] == [2]
    # its first renewal could never land inside the grant TTL
    expired = [r for r in log.of("lease") if r.event == "expired"]
    assert [(r.worker, r.t) for r in expired] == [(2, 6.0)]


def test_heartbeat_delayed_just_past_ttl_false_positive_tracker_level():
    cfg = LeaseConfig(ttl=5.0, heartbeat_period=2.0)
    tr = LeaseTracker()
    tr.grant(0, 0.0, cfg, delay=0.5)  # renewals at 2.5, 4.5, ... < ttl
    assert tr.next_expiry() == math.inf
    tr.grant(1, 0.0, cfg, delay=3.5)  # first renewal at 5.5 > ttl=5
    assert tr.next_expiry() == pytest.approx(5.0)
    assert tr.pop_expired(5.0) == [1]
    assert 0 in tr and 1 not in tr
    assert tr.next_expiry() == math.inf


def test_recover_before_expiry_is_invisible():
    """A stall that resumes inside the TTL never surfaces: no expiry, no
    rejoin, no discovered churn — the control plane simply never knew."""
    log = MetricsLog()
    sim = _fleet_sim([churn.stall(10.0, worker=1),
                      churn.recover(12.0, worker=1)],
                     fleet=FleetConfig(lease=LEASE), metrics=log)
    sim.run(30.0)
    assert not [r for r in log.of("lease") if r.event in ("expired", "rejoined")]
    assert not [r for r in log.of("churn") if r.discovered]
    assert sim.num_workers == 3
    assert sim._dead_time == 0.0
    w = sim.worker_by_id(1)
    assert w.status != "stalled" and w.steps > 0


def test_rejoin_after_expiry_catches_up():
    """Recovery after the lease expired is a discovered rejoin: a
    WorkerJoined(discovered=True), a state catch-up over the partial
    shard-pull path, and the offline span excluded from active time."""
    log = MetricsLog()
    sim = _fleet_sim([churn.stall(10.0, worker=1),
                      churn.recover(30.0, worker=1)],
                     fleet=FleetConfig(lease=LEASE), metrics=log, n_shards=4)
    sim.run(60.0)
    assert [(r.worker, r.t) for r in log.of("lease")
            if r.event == "expired"] == [(1, 16.0)]
    assert [(r.worker, r.t) for r in log.of("lease")
            if r.event == "rejoined"] == [(1, 30.0)]
    disc = [r for r in log.of("churn") if r.discovered]
    assert [(r.event, r.worker) for r in disc] == [("leave", 1), ("join", 1)]
    assert sim.num_workers == 3
    # dead from discovery (16) to rejoin (30): not counted as active
    assert sim._dead_time == pytest.approx(14.0)
    w = sim.worker_by_id(1)
    assert w.status != "catching_up" and w.steps > 0


def test_lease_expiry_mid_probe_restarts_search():
    """A lease expiry inside an Alg. 1 probe window is fleet churn: the
    window is discarded and the climb restarts — but ONLY because the
    lease layer turned the silent stall into a WorkerLeft. The same stall
    without a fleet monitor is invisible and nothing restarts."""
    def run(fleet):
        policy = make_policy("adsp", gamma=20.0, search=True,
                             probe_seconds=30.0, max_probes=4)
        profiles = ratio_profiles((1, 1, 3), base_v=1.0, o=0.2)
        cfg = SimConfig(gamma=20.0, epoch_seconds=200.0, base_batch=32,
                        max_seconds=4000.0, local_lr=0.05)
        sim = Simulator(svm_task(3), profiles, policy, cfg,
                        churn=ChurnSchedule([churn.stall(10.0, worker=2)]),
                        fleet=fleet)
        sim.engine.epoch_end()  # expiry at t=16 lands in the first window
        return sim, policy

    sim, policy = run(FleetConfig(lease=LEASE))
    assert len(policy.traces) == 1
    tr = policy.traces[0]
    assert tr.restarts >= 1
    assert tr.chosen in tr.candidates
    assert all(np.isfinite(r) for r in tr.rewards)
    assert sim.num_workers == 2
    assert policy.c_target == tr.chosen
    sim.run(50.0)
    assert all(w.steps > 0 for w in sim.workers)

    _, blind = run(None)  # no lease layer: the stall stays silent
    assert blind.traces[0].restarts == 0


def test_discovered_failure_triggers_drift_search():
    """on_worker_lost feeds the drift detector *bypassing* the TV
    threshold: with a threshold no ordinary churn could reach (0.9), the
    discovery alone re-searches, at the discovery instant."""
    policy = make_policy("adsp", gamma=20.0, search=True, search_mode="drift",
                         drift_threshold=0.9, drift_cooldown=1.0,
                         probe_seconds=10.0, max_probes=3)
    cfg = SimConfig(gamma=20.0, epoch_seconds=1e9, base_batch=32,
                    max_seconds=4000.0, local_lr=0.05)
    profiles = ratio_profiles((1.0, 1.0, 1.0), base_v=1.0, o=0.2)
    sim = Simulator(svm_task(3), profiles, policy, cfg,
                    churn=ChurnSchedule([churn.stall(10.0, worker=1)]),
                    fleet=FleetConfig(lease=LEASE))
    sim.run(100.0)
    assert len(policy.traces) >= 1
    assert policy.traces[0].t_start == pytest.approx(16.0)


# ---------------------------------------------------------------------------
# Scripted-vs-discovered departure dedupe (regression)
# ---------------------------------------------------------------------------


def test_scripted_leave_racing_missed_lease_dedupes():
    """Discovery first (t=16), administrative notice second (t=20): the
    scripted leave must consume the parked discovery, not raise on the
    already-removed worker — exactly one WorkerLeft total. Without the
    ``_lease_gone`` guard in ``_apply_churn`` this run dies with a
    KeyError at t=20."""
    log = MetricsLog()
    sim = _fleet_sim([churn.stall(10.0, worker=1),
                      churn.leave(20.0, worker=1)],
                     fleet=FleetConfig(lease=LEASE), metrics=log)
    sim.run(40.0)
    leaves = [r for r in log.of("churn") if r.event == "leave"]
    assert len(leaves) == 1 and leaves[0].worker == 1 and leaves[0].discovered
    assert 1 not in sim._lease_gone  # parking consumed: no ghost rejoin
    assert sim.num_workers == 2


def test_scripted_leave_before_expiry_cancels_discovery():
    """Notice first (t=12), lease deadline later (t=16): forgetting the
    lease must guarantee the expiry never also fires — one WorkerLeft,
    and it is the administrative (non-discovered) one."""
    log = MetricsLog()
    sim = _fleet_sim([churn.stall(10.0, worker=1),
                      churn.leave(12.0, worker=1)],
                     fleet=FleetConfig(lease=LEASE), metrics=log)
    sim.run(40.0)
    leaves = [r for r in log.of("churn") if r.event == "leave"]
    assert len(leaves) == 1 and not leaves[0].discovered
    assert not [r for r in log.of("lease") if r.event == "expired"]


# ---------------------------------------------------------------------------
# Device scheduler
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", scheduler_names())
def test_scheduler_fractions_normalized(name):
    table = {0: 1.0, 1: 4.0, 2: 0.5, 7: 2.0}
    asg = get_scheduler(name).assign(table)
    assert set(asg.fractions) == set(table)
    assert sum(asg.fractions.values()) == pytest.approx(1.0)
    assert sum(asg.data_shares.values()) == pytest.approx(1.0)
    assert all(f > 0 for f in asg.fractions.values())


@pytest.mark.parametrize("name", scheduler_names())
def test_scheduler_degenerate_capability_table_falls_back_uniform(name):
    asg = get_scheduler(name).assign({0: 0.0, 1: 0.0})
    assert asg.fractions == pytest.approx({0: 0.5, 1: 0.5})


def test_proportional_floor_guarantee():
    sched = get_scheduler("proportional", floor=0.25)
    asg = sched.assign({0: 100.0, 1: 1.0, 2: 1.0})
    assert all(f >= 0.25 / 3 - 1e-12 for f in asg.fractions.values())
    assert asg.fractions[0] > 0.7  # the fast device still dominates


def test_sqrt_sits_between_uniform_and_proportional():
    table = {0: 1.0, 1: 4.0}
    prop = get_scheduler("proportional", floor=0.0).assign(table).fractions
    sq = get_scheduler("sqrt").assign(table).fractions
    assert 0.5 < sq[1] < prop[1]  # flattens toward uniform, keeps order


def test_unknown_scheduler_names_the_known_ones():
    with pytest.raises(KeyError, match="proportional"):
        get_scheduler("nope")


def test_capability_report_lags_to_next_heartbeat():
    """set_speed changes ground truth at t=3, but the scheduler only sees
    it when the next heartbeat (sent at t=4, period 2) arrives — until
    then assignments run on the stale report."""
    log = MetricsLog()
    sim = _fleet_sim([churn.speed(3.0, worker=0, v=5.0)],
                     fleet=FleetConfig(lease=LEASE, scheduler="proportional"),
                     metrics=log)
    sim.run(10.0)
    caps = [r for r in log.of("capability") if r.worker == 0 and r.v == 5.0]
    assert caps and caps[0].t == pytest.approx(4.0)
    asg0 = [r for r in log.of("assign") if r.worker == 0 and r.t == 0.0]
    assert asg0 and asg0[0].fraction == pytest.approx(1 / 3)  # equal fleet
    asg4 = [r for r in log.of("assign")
            if r.worker == 0 and r.t == pytest.approx(4.0)]
    assert asg4 and asg4[0].fraction > 0.5  # v=5 vs 1,1 after the report


def test_scheduled_run_trains_end_to_end():
    log = MetricsLog()
    profiles = fleet_profiles(4, spread=4.0, seed=1, o=0.2)
    sim = _fleet_sim([], profiles=profiles, metrics=log,
                     fleet=FleetConfig(lease=LEASE, scheduler="sqrt"))
    sim.run(30.0)
    assert all(w.steps > 0 for w in sim.workers)
    assert len(log.of("assign")) >= len(profiles)  # at least the join pass
    assert len(log.of("commit")) > 0 and len(log.of("eval")) > 0


# ---------------------------------------------------------------------------
# Lease tracker scale behaviour (the no-per-period-timers contract)
# ---------------------------------------------------------------------------


def test_lease_tracker_batch_expiry_at_scale():
    cfg = LeaseConfig(ttl=30.0, heartbeat_period=10.0)
    tr = LeaseTracker()
    for wid in range(2000):
        tr.grant(wid, 0.0, cfg, delay=0.0)
    # a healthy fleet schedules ZERO pending expiries, whatever its size
    assert tr.next_expiry() == math.inf
    for wid in range(100):
        tr.stall(wid, 100.0)
    for wid in range(0, 100, 2):
        assert tr.recover(wid, 105.0)  # resumed inside the TTL
    deadline = tr.next_expiry()
    assert math.isfinite(deadline)
    gone = tr.pop_expired(deadline + cfg.ttl)  # one batch drain
    assert sorted(gone) == list(range(1, 100, 2))
    assert tr.next_expiry() == math.inf
    assert len(tr) == 2000 - 50


def test_lease_tracker_recover_at_deadline_still_expires():
    """Recovering exactly AT the deadline loses the race: the expiry
    stands and the caller must take the rejoin path (returns False)."""
    cfg = LeaseConfig(ttl=6.0, heartbeat_period=2.0)
    tr = LeaseTracker()
    tr.grant(0, 0.0, cfg, delay=0.0)
    tr.stall(0, 10.0)
    assert not tr.recover(0, 16.0)  # tie goes to the expiry
    assert tr.pop_expired(16.0) == [0]


# ---------------------------------------------------------------------------
# Metrics registry + sinks
# ---------------------------------------------------------------------------

SAMPLE_RECORDS = [
    CommitRecord(t=1.5, worker=3, latency=0.7, push_bytes=1e6,
                 pull_bytes=2e6, stale_shards=2, n_shards=8),
    EvalRecord(t=2.0, loss=0.123),
    SearchRecord(t=3.0, chosen=4, windows=5, restarts=1, aborted=False),
    DriftRecord(t=4.0, cause="worker_left"),
    LeaseRecord(t=5.0, worker=1, event="expired"),
    ChurnRecord(t=6.0, worker=1, event="leave", discovered=True),
    CapabilityRecord(t=7.0, worker=2, v=3.5),
    AssignRecord(t=8.0, worker=2, fraction=0.4, data_share=0.4),
    ServeRecord(t=9.0, req=5, queue=0.01, prefill=0.004, decode=0.05,
                total=0.064, tokens=9, slo=0.8, slo_ok=True, version=3),
    PullRecord(t=10.0, stale_shards=2, n_shards=4, nbytes=2048.0),
]


def test_sample_records_cover_every_registered_kind():
    assert {r.kind for r in SAMPLE_RECORDS} == set(record_kinds())


@pytest.mark.parametrize("rec", SAMPLE_RECORDS, ids=lambda r: r.kind)
def test_record_roundtrips_through_json(rec):
    assert from_dict(json.loads(json.dumps(to_dict(rec)))) == rec


def test_from_dict_unknown_kind_names_known_kinds():
    with pytest.raises(KeyError, match="lease"):
        from_dict({"kind": "bogus", "t": 0.0})


def test_metrics_log_roundtrips_through_jsonl(tmp_path):
    log = MetricsLog.from_records(SAMPLE_RECORDS)
    assert len(log) == len(SAMPLE_RECORDS)
    assert log.of("lease") == [SAMPLE_RECORDS[4]]
    path = tmp_path / "stream.jsonl"
    log.to_jsonl(path)
    assert load_jsonl(path) == SAMPLE_RECORDS


def test_jsonl_sink_streams_as_emitted(tmp_path):
    path = tmp_path / "live.jsonl"
    with JsonlSink(path) as sink:
        sink.record(SAMPLE_RECORDS[0])
        # flushed per record: a crashed run keeps its prefix
        assert load_jsonl(path) == SAMPLE_RECORDS[:1]
        sink.record(SAMPLE_RECORDS[1])
    assert load_jsonl(path) == SAMPLE_RECORDS[:2]


# ---------------------------------------------------------------------------
# tools/fleet_report.py
# ---------------------------------------------------------------------------


def _fleet_report_module():
    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "fleet_report", root / "tools" / "fleet_report.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fleet_report_summarize_and_format():
    fr = _fleet_report_module()
    s = fr.summarize(SAMPLE_RECORDS)
    assert s["t_end"] == 10.0
    assert s["searches"] == 1 and s["drift_triggers"] == 1
    assert s["serve"]["requests"] == 1 and s["serve"]["slo_ok"] == 1
    assert s["pulls"]["polls"] == 1 and s["pulls"]["nbytes"] == 2048.0
    assert s["lease"]["expired"] == 1
    assert s["churn"]["leave"] == 1 and s["discovered"] == 1
    assert s["assigns"] == 1 and s["capability_reports"] == 1
    assert s["per_worker"][3]["commits"] == 1
    assert s["per_worker"][3]["stale_shards"] == 2
    out = fr.format_report(s)
    assert "fleet report" in out and "stale_ratio" in out
    assert "drift triggers: 1" in out
    assert "serving: 1 requests" in out and "SLO attainment 100.0%" in out


def test_fleet_report_on_a_real_stream(tmp_path):
    fr = _fleet_report_module()
    log = MetricsLog()
    sim = _fleet_sim([churn.stall(10.0, worker=1)],
                     fleet=FleetConfig(lease=LEASE, scheduler="proportional"),
                     metrics=log)
    sim.run(40.0)
    path = tmp_path / "run.jsonl"
    log.to_jsonl(path)
    s = fr.summarize(load_jsonl(path))
    assert s["lease"]["granted"] == 3 and s["lease"]["expired"] == 1
    assert s["discovered"] == 1
    assert len(s["per_worker"]) >= 2
    assert "lease:" in fr.format_report(s)
