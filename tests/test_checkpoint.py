import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree, save_train_state, load_train_state


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(10, dtype=jnp.float32),
        "b": {"c": jnp.ones((3, 4), jnp.bfloat16), "d": jnp.asarray(3, jnp.int32)},
        "e": [jnp.zeros(2), jnp.ones(2)],
    }
    p = tmp_path / "ckpt.npz"
    save_pytree(p, tree, metadata={"step": 7})
    restored, meta = load_pytree(p, like=tree)
    assert meta["step"] == 7
    for a, b in zip(__import__("jax").tree.leaves(tree), __import__("jax").tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_missing_leaf_raises(tmp_path):
    p = tmp_path / "c.npz"
    save_pytree(p, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        load_pytree(p, like={"a": jnp.zeros(3), "b": jnp.zeros(3)})


def test_train_state_helpers(tmp_path):
    from repro.core.commit import AdspState

    state = AdspState.create({"w": jnp.ones((4, 4))})
    p = tmp_path / "s.npz"
    save_train_state(p, state, step=42, extra={"arch": "granite"})
    restored, meta = load_train_state(p, like=state)
    assert meta == {"step": 42, "arch": "granite"}
    np.testing.assert_array_equal(np.asarray(restored.params["w"]), np.ones((4, 4)))
