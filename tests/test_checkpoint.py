import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree, save_train_state, load_train_state


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(10, dtype=jnp.float32),
        "b": {"c": jnp.ones((3, 4), jnp.bfloat16), "d": jnp.asarray(3, jnp.int32)},
        "e": [jnp.zeros(2), jnp.ones(2)],
    }
    p = tmp_path / "ckpt.npz"
    save_pytree(p, tree, metadata={"step": 7})
    restored, meta = load_pytree(p, like=tree)
    assert meta["step"] == 7
    for a, b in zip(__import__("jax").tree.leaves(tree), __import__("jax").tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_missing_leaf_raises(tmp_path):
    p = tmp_path / "c.npz"
    save_pytree(p, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        load_pytree(p, like={"a": jnp.zeros(3), "b": jnp.zeros(3)})


def test_train_state_helpers(tmp_path):
    from repro.ps import AdspState

    state = AdspState.create({"w": jnp.ones((4, 4))})
    p = tmp_path / "s.npz"
    save_train_state(p, state, step=42, extra={"arch": "granite"})
    restored, meta = load_train_state(p, like=state)
    assert meta == {"step": 42, "arch": "granite"}
    np.testing.assert_array_equal(np.asarray(restored.params["w"]), np.ones((4, 4)))


def test_save_leaves_no_temp_files(tmp_path):
    """Atomic save hygiene: after any number of saves only the target
    exists — np.savez must not leave the mkstemp original behind (it
    appends '.npz' to paths that lack the suffix)."""
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3,))}}
    for i in range(3):
        save_pytree(tmp_path / "ckpt.npz", tree, metadata={"i": i})
    assert sorted(p.name for p in tmp_path.iterdir()) == ["ckpt.npz"]
    restored, meta = load_pytree(tmp_path / "ckpt.npz", like=tree)
    assert meta["i"] == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(8.0))


def test_failed_save_cleans_up_and_keeps_previous(tmp_path, monkeypatch):
    """A crash mid-write must leave no partial temp file and must not
    clobber the previous checkpoint (temp-file + atomic rename)."""
    tree = {"a": jnp.arange(4.0)}
    target = tmp_path / "ckpt.npz"
    save_pytree(target, tree, metadata={"ok": 1})

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        save_pytree(target, tree, metadata={"ok": 2})
    monkeypatch.undo()
    assert sorted(p.name for p in tmp_path.iterdir()) == ["ckpt.npz"]
    _, meta = load_pytree(target, like=tree)
    assert meta["ok"] == 1  # previous checkpoint intact
